"""Benchmark driver: one function per paper figure/table.

  fig1   - IID accuracy + Bpp vs rounds (paper Fig. 1)
  fig2   - non-IID lambda trade-off vs baselines (paper Fig. 2)
  kernels- masked-matmul / bitpack micro-benchmarks
  roofline (separate: python -m benchmarks.roofline dryrun_results.json)

Prints ``name,us_per_call,derived`` CSV blocks per benchmark.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 36
    from benchmarks import fig1_iid, fig2_noniid, kernels_bench

    print("== kernels ==")
    kernels_bench.main()

    print("== fig1 (IID) ==")
    t0 = time.time()
    fig1_iid.main(rounds=rounds, k=6, datasets=["mnist-like",
                                                "cifar10-like"])
    print(f"# fig1 wall: {time.time()-t0:.0f}s", file=sys.stderr)

    print("== fig2 (non-IID) ==")
    t0 = time.time()
    fig2_noniid.main(rounds=max(rounds // 2, 8), k=6, c=2)
    print(f"# fig2 wall: {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
