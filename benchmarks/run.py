"""Benchmark driver: one function per paper figure/table.

  fig1   - IID accuracy + Bpp vs rounds (paper Fig. 1)
  fig2   - non-IID lambda trade-off vs baselines (paper Fig. 2)
  kernels- masked-matmul / bitpack micro-benchmarks
  roofline (separate: python -m benchmarks.roofline dryrun_results.json)

Prints ``name,us_per_call,derived`` CSV blocks per benchmark and writes
``bench_results.json`` — wall-clock plus every run's CommLedger
(cumulative_uplink_mb / cumulative_downlink_mb), so the perf trajectory
captures communication, not just speed.
"""
from __future__ import annotations

import json
import sys
import time


def main() -> None:
    rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 36
    from benchmarks import fig1_iid, fig2_noniid, kernels_bench

    results = {"rounds": rounds}

    print("== kernels ==")
    kernels_bench.main()

    print("== fig1 (IID) ==")
    t0 = time.time()
    gains = fig1_iid.main(rounds=rounds, k=6, datasets=["mnist-like",
                                                        "cifar10-like"])
    results["fig1_wall_s"] = time.time() - t0
    results["fig1"] = gains
    print(f"# fig1 wall: {results['fig1_wall_s']:.0f}s", file=sys.stderr)

    print("== fig2 (non-IID) ==")
    t0 = time.time()
    runs = fig2_noniid.main(rounds=max(rounds // 2, 8), k=6, c=2)
    results["fig2_wall_s"] = time.time() - t0
    results["fig2"] = {
        ds: {name: dict(acc=hist["acc"][-1], bpp=hist["bpp"][-1],
                        **hist["ledger"])
             for name, hist in by_algo.items()}
        for ds, by_algo in runs.items()}
    print(f"# fig2 wall: {results['fig2_wall_s']:.0f}s", file=sys.stderr)

    with open("bench_results.json", "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print("# wrote bench_results.json", file=sys.stderr)


if __name__ == '__main__':
    main()
