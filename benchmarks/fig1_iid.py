"""Paper Fig. 1: IID — validation accuracy & average Bpp vs rounds,
FedPM vs FedPM+regularization (lambda=1), three datasets.

Accuracy is also tracked against the CommLedger's cumulative two-way
traffic (accuracy-vs-MB, the paper's communication x-axis).

Prints CSV: dataset,algo,round,acc,bpp,bpp_measured,sparsity,cum_mb
"""
from __future__ import annotations

import sys

from benchmarks import common


def main(rounds: int = 12, k: int = 10, datasets=None):
    datasets = datasets or ["mnist-like", "cifar10-like",
                            "cifar100-like"]
    print("dataset,algo,round,acc,bpp,bpp_measured,sparsity,cum_mb")
    summary = []
    for ds in datasets:
        setup = common.make_setup(ds, k=k, c=None)
        # both variants resolve through the registry: "fedpm" is the
        # lam=0 reference, "fedpm_reg" the paper's method
        for algo, name, kw in [("fedpm", "fedpm", {}),
                               ("fedpm_reg", "fedpm+reg", dict(lam=1.0)),
                               ("fedpm_reg", "fedpm+reg4",
                                dict(lam=4.0))]:
            hist, _ = common.run_algorithm(setup, algo, rounds, lr=0.1,
                                           optimizer="adam",
                                           float_lr=1e-3, **kw)
            for r in range(rounds):
                cum = (hist["cumulative_uplink_mb"][r]
                       + hist["cumulative_downlink_mb"][r])
                print(f"{ds},{name},{r},{hist['acc'][r]:.4f},"
                      f"{hist['bpp'][r]:.4f},"
                      f"{hist['bpp_measured'][r]:.4f},"
                      f"{hist['sparsity'][r]:.4f},{cum:.4f}")
            summary.append((ds, name, hist["acc"][-1], hist["bpp"][-1],
                            hist["ledger"]))
    print("# summary: dataset algo final_acc final_bpp cum_mb",
          file=sys.stderr)
    gains = {}
    for ds, name, acc, bpp, ledger in summary:
        print(f"# {ds:14s} {name:10s} acc={acc:.3f} bpp={bpp:.3f} "
              f"up={ledger['cumulative_uplink_mb']:.3f}MB "
              f"down={ledger['cumulative_downlink_mb']:.3f}MB",
              file=sys.stderr)
        gains.setdefault(ds, {})[name] = dict(acc=acc, bpp=bpp, **ledger)
    for ds, g in gains.items():
        for variant in ("fedpm+reg", "fedpm+reg4"):
            if variant in g and "fedpm" in g:
                dbpp = g["fedpm"]["bpp"] - g[variant]["bpp"]
                dacc = g["fedpm"]["acc"] - g[variant]["acc"]
                print(f"# {ds} {variant}: Bpp saved={dbpp:+.3f}, "
                      f"acc delta={-dacc:+.3f} (paper trend: reg saves "
                      "Bpp at ~0 acc cost; grows with rounds/lambda)",
                      file=sys.stderr)
    return gains


if __name__ == "__main__":
    rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 12
    main(rounds)
