"""Paper Fig. 1: IID — validation accuracy & average Bpp vs rounds,
FedPM vs FedPM+regularization (lambda=1), three datasets.

Prints CSV: dataset,algo,round,acc,bpp,sparsity
"""
from __future__ import annotations

import sys

from benchmarks import common


def main(rounds: int = 12, k: int = 10, datasets=None):
    datasets = datasets or ["mnist-like", "cifar10-like",
                            "cifar100-like"]
    print("dataset,algo,round,acc,bpp,sparsity")
    summary = []
    for ds in datasets:
        setup = common.make_setup(ds, k=k, c=None)
        # both variants resolve through the registry: "fedpm" is the
        # lam=0 reference, "fedpm_reg" the paper's method
        for algo, name, kw in [("fedpm", "fedpm", {}),
                               ("fedpm_reg", "fedpm+reg", dict(lam=1.0)),
                               ("fedpm_reg", "fedpm+reg4",
                                dict(lam=4.0))]:
            hist, _ = common.run_algorithm(setup, algo, rounds, lr=0.1,
                                           optimizer="adam",
                                           float_lr=1e-3, **kw)
            for r in range(rounds):
                print(f"{ds},{name},{r},{hist['acc'][r]:.4f},"
                      f"{hist['bpp'][r]:.4f},{hist['sparsity'][r]:.4f}")
            summary.append((ds, name, hist["acc"][-1], hist["bpp"][-1]))
    print("# summary: dataset algo final_acc final_bpp", file=sys.stderr)
    gains = {}
    for ds, name, acc, bpp in summary:
        print(f"# {ds:14s} {name:10s} acc={acc:.3f} bpp={bpp:.3f}",
              file=sys.stderr)
        gains.setdefault(ds, {})[name] = (acc, bpp)
    for ds, g in gains.items():
        for variant in ("fedpm+reg", "fedpm+reg4"):
            if variant in g and "fedpm" in g:
                dbpp = g["fedpm"][1] - g[variant][1]
                dacc = g["fedpm"][0] - g[variant][0]
                print(f"# {ds} {variant}: Bpp saved={dbpp:+.3f}, "
                      f"acc delta={-dacc:+.3f} (paper trend: reg saves "
                      "Bpp at ~0 acc cost; grows with rounds/lambda)",
                      file=sys.stderr)
    return gains


if __name__ == "__main__":
    rounds = int(sys.argv[1]) if len(sys.argv) > 1 else 12
    main(rounds)
